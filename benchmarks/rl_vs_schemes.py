"""BENCH: the pool-wide PPO controller vs the classical schedulers.

Paper §V end state (Fig 10): one RL controller manages the *whole*
heterogeneous pool.  This benchmark trains the factored-action PPO
controller (:func:`repro.core.rl.train_ppo_pool`) on scenario batches —
every episode a fresh seeded realization sampled from the
:data:`~repro.core.workloads.SCENARIO_ZOO` — then deploys it through
the ``vectorized`` scheduler interface (``VECTOR_SCHEDULERS["rl_pool"]``)
and evaluates it head-to-head against all six classical vectorized
schedulers on held-out realizations of every zoo scenario.

Artifact: ``BENCH_rl_pool.json`` — per (scenario, scheduler) summaries,
training history, the pool-rollout throughput at A=64, and a ``claims``
block that reports — win or lose — the cost/violation gap between the
trained controller and the best classical scheme per scenario.

On full runs the trained parameters are published to
``artifacts/rl/pool_policy.json`` (the default checkpoint a bare
``RLPoolPolicy()`` loads); ``BENCH_SMALL=1`` smoke runs shrink the
training and evaluation sizes and do NOT overwrite the checkpoint.

PR 8 adds the ``claims.fleet_scale`` section — the ROADMAP fleet-scale
generalization study.  Training goes *full-zoo*: every PPO iteration
collects all S zoo scenarios as one ``[S, T, A]`` batched scan dispatch
(:func:`repro.core.rl.ppo.collect_rollouts_jax_zoo`) instead of one
sampled scenario, so each gradient step sees the whole distribution.
Controllers trained full-zoo at A=8 and A=16 are then deployed
zero-shot on A=64 and A=256 pools with the variant catalog attached
and the spot head live (the full 108-action space acting on real
state), head-to-head against classical baselines including the
variant-aware ``infaas_variant`` — train-small / deploy-fleet is the
self-managed-at-scale property the paper's §V sketches.

PR 9 closes the variant-head training-fidelity gap: the main training
env now carries the :class:`~repro.core.sim.VariantCatalog`, and since
the in-scan ``rl_sample`` decode executes the 3-way variant head (the
variant axis lives inside the jitted scan), the batched rollout
collectors train on real swap dynamics instead of a frozen base-variant
fleet.  ``claims.variant_head_live`` evaluates the previously-committed
checkpoint (trained variant-blind at fleet speed) and the retrained one
on the same catalog-attached held-out evals and reports the blended-
objective delta; its claim row requires the deployed controller to
actually exercise the swap pipeline (liveness, not superiority — the
delta is honest either way and recorded win or lose).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.rl import (
    EnvConfig,
    PPOConfig,
    PoolServingEnv,
    RLPoolPolicy,
    load_policy_params,
    pool_policy_action,
    save_policy_params,
    train_ppo_pool,
)
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import (
    VariantCatalog,
    replicate_pool,
    simulate,
    uniform_pool_workload,
)
from repro.core.workloads import SCENARIO_ZOO

PENALTY = 0.02                     # $ per violated request (blended objective)
MEAN_RPS = 150.0 if BENCH_SMALL else 400.0   # heavy enough that per-arch
                                   # fleets hold multiple instances — fleet
                                   # sizing, not the 1-instance floor, must
                                   # dominate cost for headroom to matter
TRAIN_DURATION_S = 240 if BENCH_SMALL else 900
EVAL_DURATION_S = 240 if BENCH_SMALL else 1800
# the batched in-scan rollout collector (PR 6) cut rollout collection
# ~2.6x at this pool size, so the full-run training budget grew 64 ->
# 192 iterations at LESS wall-clock than the old step-wise 64 — which
# is what converges the 108-action policy far enough that its greedy
# argmax deployment is competitive (the explicit (seed, tick) tier
# randomness landed in the same PR and perturbed the old 64-iteration
# optimum)
ITERATIONS = 4 if BENCH_SMALL else 192
# the spot head tripled the action space (36 -> 108); the entropy bonus
# that kept a 36-action policy exploring keeps a 108-action policy
# near-uniform for the whole training budget, so it is effectively
# disabled here (PPO's clipped updates + the best-snapshot guard cover
# premature collapse at this scale)
ENTROPY_COEF = 0.0005
EVAL_SEED_OFFSET = 4242            # held-out realizations of each scenario
CLASSICAL = ("reactive", "util_aware", "exascale", "mixed", "paragon",
             "spot_paragon")
# full runs train with the batched in-scan rollout collector
# (:func:`repro.core.rl.ppo.collect_rollouts_jax`) — one jitted
# dispatch per episode instead of T host round-trips, which is what
# pays for the 192-iteration budget above; RL_JAX_ROLLOUTS=0/1
# overrides (smoke runs default to the step-wise env loop so the
# host path stays exercised in CI).  Either way the collector's
# throughput delta is measured and recorded in the artifact.
_jr_env = os.environ.get("RL_JAX_ROLLOUTS", "")
JAX_ROLLOUTS = _jr_env == "1" if _jr_env else not BENCH_SMALL
# fleet-scale generalization study (claims.fleet_scale): full-zoo
# training pools, zero-shot deployment pools, and the budget for the
# study's own training runs (the A=16 controller always trains here;
# the A=8 one reuses the main run when that run was full-zoo)
FLEET_TRAIN_POOLS = (8, 16)
FLEET_EVAL_POOLS = (64, 256)
FLEET_ITERATIONS = 2 if BENCH_SMALL else 96
FLEET_EVAL_SCENARIOS = ("mmpp_bursts", "flash_anti")
FLEET_CLASSICAL = ("reactive", "paragon", "infaas_variant")


def _objective(summary: dict, total_requests: float) -> float:
    return summary["cost_total"] + PENALTY * summary["violation_rate"] * total_requests


def _rollout_throughput_64(params, cfg: EnvConfig) -> dict:
    """Env+policy rollout speed at a 64-arch pool (the training path)."""
    import jax

    wl = replicate_pool(SERVING_POOL, 64, strict_frac=STRICT_FRAC)
    sc = SCENARIO_ZOO["mmpp_bursts"]
    ticks = 120 if BENCH_SMALL else 600
    arrivals = sc.build(len(wl), duration_s=ticks, mean_rps=MEAN_RPS)
    env = PoolServingEnv(wl, cfg, arrivals=arrivals)
    obs = env.reset()
    key = jax.random.key(0)
    pool_policy_action(params, obs, key)    # compile outside the clock
    t0 = time.perf_counter()
    steps = 0
    done = False
    while not done:
        key, k = jax.random.split(key)
        a, _, _ = pool_policy_action(params, obs, k)
        obs, _, done, _ = env.step(a)
        steps += 1
    wall = time.perf_counter() - t0
    out = {"pool_size": 64, "ticks": steps, "wall_s": wall,
           "ticks_per_s": steps / wall}

    # the batched collector on the same episode: one jitted dispatch
    # instead of `ticks` host round-trips
    from repro.core.rl.ppo import collect_rollouts_jax

    kroll = jax.random.key(0)
    collect_rollouts_jax(env, params, kroll)    # compile outside the clock
    t0 = time.perf_counter()
    collect_rollouts_jax(env, params, kroll)
    jwall = time.perf_counter() - t0
    out["jax_collector"] = {
        "wall_s": jwall,
        "ticks_per_s": steps / jwall,
        "speedup_vs_env_loop": wall / jwall,
    }
    return out


def _train_full_zoo(A: int, iterations: int, seed: int) -> tuple:
    """One full-zoo-trained controller at pool size ``A``: every PPO
    iteration collects the whole ``SCENARIO_ZOO`` as one ``[S, T, A]``
    batched scan dispatch (``collect_rollouts_jax_zoo``).  Per-arch
    demand is held at the A=8 training level."""
    wl = (uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
          if A == len(SERVING_POOL)
          else replicate_pool(SERVING_POOL, A, strict_frac=STRICT_FRAC))
    rps = MEAN_RPS * A / len(SERVING_POOL)
    cfg = EnvConfig(strict_frac=STRICT_FRAC, mean_rps=rps,
                    duration_s=TRAIN_DURATION_S, violation_penalty=PENALTY)
    env = PoolServingEnv(wl, cfg, scenarios=list(SCENARIO_ZOO.values()),
                         scenario_seed=seed)
    t0 = time.perf_counter()
    state = train_ppo_pool(
        env,
        PPOConfig(iterations=iterations, rollout_len=TRAIN_DURATION_S,
                  entropy_coef=ENTROPY_COEF, seed=seed),
        jax_rollouts=True, full_zoo=True,
    )
    hist = state.history
    info = {
        "pool_size": A, "mean_rps": rps, "iterations": iterations,
        "full_zoo": True, "zoo_size": len(SCENARIO_ZOO),
        "wall_s": round(time.perf_counter() - t0, 2),
        "reward_first": hist[0]["rollout_reward"],
        "reward_last": hist[-1]["rollout_reward"],
        "reward_best": state.best_reward,
    }
    return state.params, info


def _fleet_generalization(state) -> dict:
    """The fleet-scale generalization study (ROADMAP open item 6):
    full-zoo-trained A=8 / A=16 controllers deployed zero-shot on
    A=64 / A=256 pools with the variant catalog attached and the spot
    head live — the full 108-action joint space acting on real state —
    against classical baselines including the variant-aware
    ``infaas_variant``.  The ratio fields report the gap win or lose;
    the claim rows only require the study to be complete and finite."""
    params: Dict[str, dict] = {}
    trained: Dict[str, dict] = {}
    for A in FLEET_TRAIN_POOLS:
        if A == len(SERVING_POOL) and JAX_ROLLOUTS:
            # the main training run above IS a full-zoo A=8 controller
            # (and a better-trained one than the study budget buys)
            params[str(A)] = state.params
            trained[str(A)] = {
                "pool_size": A, "source": "main_training",
                "iterations": len(state.history), "full_zoo": True,
                "zoo_size": len(SCENARIO_ZOO),
                "reward_best": state.best_reward,
            }
        else:
            params[str(A)], trained[str(A)] = _train_full_zoo(
                A, FLEET_ITERATIONS, seed=20 + A
            )
    out = {
        "train": trained,
        "eval_scenarios": list(FLEET_EVAL_SCENARIOS),
        "classical": list(FLEET_CLASSICAL),
        "variant_catalog": True,
        "eval_duration_s": EVAL_DURATION_S,
        "eval": {},
        "median_obj_ratio": {},
    }
    for A in FLEET_EVAL_POOLS:
        wlA = replicate_pool(SERVING_POOL, A, strict_frac=STRICT_FRAC)
        catalog = VariantCatalog.for_workload(wlA)
        rpsA = MEAN_RPS * A / len(SERVING_POOL)
        grid: Dict[str, dict] = {}
        ratios: List[float] = []
        for name in FLEET_EVAL_SCENARIOS:
            sc = SCENARIO_ZOO[name]
            arrivals = sc.build(
                A, seed=sc.seed + EVAL_SEED_OFFSET + 2,
                duration_s=EVAL_DURATION_S, mean_rps=rpsA,
            )
            cell: Dict[str, dict] = {}
            for pol_name in FLEET_CLASSICAL:
                res = simulate(arrivals, wlA,
                               VECTOR_SCHEDULERS[pol_name](),
                               catalog=catalog)
                cell[pol_name] = {
                    **res.summary(),
                    "objective": round(
                        _objective(res.summary(), res.total_requests), 4
                    ),
                }
            for At in FLEET_TRAIN_POOLS:
                res = simulate(
                    arrivals, wlA,
                    RLPoolPolicy(params=params[str(At)], greedy=True),
                    catalog=catalog,
                )
                cell[f"rl_a{At}"] = {
                    **res.summary(),
                    "objective": round(
                        _objective(res.summary(), res.total_requests), 4
                    ),
                }
            best = min(FLEET_CLASSICAL, key=lambda p: cell[p]["objective"])
            rl_best = min(
                (f"rl_a{At}" for At in FLEET_TRAIN_POOLS),
                key=lambda k: cell[k]["objective"],
            )
            cell["best_classical"] = best
            cell["rl_best"] = rl_best
            cell["rl_obj_over_best_classical"] = round(
                cell[rl_best]["objective"]
                / max(cell[best]["objective"], 1e-9), 4
            )
            ratios.append(cell["rl_obj_over_best_classical"])
            grid[name] = cell
        out["eval"][str(A)] = grid
        out["median_obj_ratio"][str(A)] = float(np.median(ratios))
    return out


def _variant_head_live(params_before, params_after, wl, catalog) -> dict:
    """Before/after A/B on catalog-attached held-out evals: the committed
    checkpoint (trained variant-blind at fleet speed — the PR 8 fidelity
    gap) vs the controller retrained with the variant head live inside
    the batched scan.  The before/after objective delta compares the two
    checkpoints greedy-vs-greedy on identical realizations (recorded win
    or lose).  Liveness — the enforced property — is measured on the
    *stochastic* deployment, which is what ``VECTOR_SCHEDULERS["rl_pool"]``
    actually ships: a converged greedy argmax may legitimately settle on
    "hold" (the blended objective carries no accuracy term), but the
    head's sampled actions must still reach the swap pipeline end to
    end, exactly as they did during training."""
    out: Dict[str, dict] = {
        "trained_with_catalog": True,
        "before_checkpoint_found": params_before is not None,
        "scenarios": {},
    }
    obj_before, obj_after = [], []
    swaps_greedy, swaps_stoch = 0, 0
    for name in ("trending_hotswap", "mmpp_bursts"):
        sc = SCENARIO_ZOO[name]
        arrivals = sc.build(
            len(wl), seed=sc.seed + EVAL_SEED_OFFSET + 3,
            duration_s=EVAL_DURATION_S, mean_rps=MEAN_RPS,
        )
        cell: Dict[str, dict] = {}
        runs = [("after", RLPoolPolicy(params=params_after, greedy=True)),
                ("after_stochastic", RLPoolPolicy(params=params_after,
                                                  seed=17))]
        if params_before is not None:
            runs.insert(0, ("before",
                            RLPoolPolicy(params=params_before, greedy=True)))
        for label, pol in runs:
            res = simulate(arrivals, wl, pol, catalog=catalog)
            cell[label] = {
                **res.summary(),
                "objective": round(
                    _objective(res.summary(), res.total_requests), 4
                ),
            }
            if label == "after":
                obj_after.append(cell[label]["objective"])
                swaps_greedy += res.variant_swaps
            elif label == "after_stochastic":
                swaps_stoch += res.variant_swaps
            else:
                obj_before.append(cell[label]["objective"])
        out["scenarios"][name] = cell
    out["objective_after"] = round(float(np.mean(obj_after)), 4)
    out["objective_before"] = (
        round(float(np.mean(obj_before)), 4) if obj_before else None
    )
    out["delta"] = (
        round(out["objective_before"] - out["objective_after"], 4)
        if obj_before else None
    )
    out["variant_swaps_greedy"] = int(swaps_greedy)
    out["variant_swaps_stochastic"] = int(swaps_stoch)
    return out


def run(iterations: int = ITERATIONS) -> bool:
    t0 = time.perf_counter()
    wl = uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    envcfg = EnvConfig(
        strict_frac=STRICT_FRAC, mean_rps=MEAN_RPS,
        duration_s=TRAIN_DURATION_S, violation_penalty=PENALTY,
    )
    scenarios = list(SCENARIO_ZOO.values())
    # the committed checkpoint, read BEFORE this run's save overwrites
    # it — the "before" side of claims.variant_head_live
    params_before = load_policy_params()

    # PR 9: the catalog rides into training — the in-scan rl_sample
    # decode executes the variant head, so every batched rollout sees
    # real swap dynamics (before this the head trained blind: its
    # actions were collected but never touched the fleet)
    catalog = VariantCatalog.for_workload(wl)
    train_env = PoolServingEnv(wl, envcfg, scenarios=scenarios,
                               scenario_seed=1, catalog=catalog)
    log_name = "training_log_small.jsonl" if BENCH_SMALL else "training_log.jsonl"
    log_path = os.path.join(
        os.path.dirname(__file__), "..", "artifacts", "rl", log_name
    )
    state = train_ppo_pool(
        train_env,
        PPOConfig(iterations=iterations, rollout_len=TRAIN_DURATION_S,
                  entropy_coef=ENTROPY_COEF, seed=0),
        jax_rollouts=JAX_ROLLOUTS,
        # full-zoo (PR 8): one [S, T, A] batched dispatch per iteration
        # covers every zoo scenario, so each gradient step trains on
        # the whole distribution instead of one sampled realization
        full_zoo=JAX_ROLLOUTS,
        log_path=log_path,
    )
    train_wall = time.perf_counter() - t0

    if not BENCH_SMALL:
        save_policy_params(
            state.params,
            meta={"iterations": iterations, "mean_rps": MEAN_RPS,
                  "duration_s": TRAIN_DURATION_S, "penalty": PENALTY,
                  "best_reward": state.best_reward,
                  "scenarios": sorted(SCENARIO_ZOO)},
            rate_scale=envcfg.rate_scale,
            fleet_scale=envcfg.fleet_scale,
        )

    # -- head-to-head on held-out realizations of every zoo scenario -------
    grid: Dict[str, dict] = {}
    wins, gaps = [], {}
    for name, sc in SCENARIO_ZOO.items():
        arrivals = sc.build(
            len(wl), seed=sc.seed + EVAL_SEED_OFFSET,
            duration_s=EVAL_DURATION_S, mean_rps=MEAN_RPS,
        )
        cell: Dict[str, dict] = {"scenario": sc.to_dict()}
        for pol_name in CLASSICAL:
            res = simulate(arrivals, wl, VECTOR_SCHEDULERS[pol_name]())
            cell[pol_name] = {
                **res.summary(),
                "objective": round(_objective(res.summary(), res.total_requests), 4),
                "violations": round(res.violations, 1),
            }
        for label, pol in (
            ("rl_pool", RLPoolPolicy(params=state.params, seed=11)),
            ("rl_pool_greedy", RLPoolPolicy(params=state.params, greedy=True)),
        ):
            res = simulate(arrivals, wl, pol)
            cell[label] = {
                **res.summary(),
                "objective": round(
                    _objective(res.summary(), res.total_requests), 4
                ),
                "violations": round(res.violations, 1),
            }

        cheapest = min(CLASSICAL, key=lambda p: cell[p]["cost_total"])
        best_obj = min(CLASSICAL, key=lambda p: cell[p]["objective"])
        # the controller's two deployment modes count as one contender:
        # the objective-best of stochastic hedging vs greedy argmax is
        # "the controller" in every gap/win field below (under the
        # 108-action space greedy is usually the stronger deployment)
        rl_best_label = min(
            ("rl_pool", "rl_pool_greedy"),
            key=lambda label: cell[label]["objective"],
        )
        rl_best = cell[rl_best_label]
        win = (
            rl_best["cost_total"] < cell[cheapest]["cost_total"]
            and rl_best["violations"] <= cell[cheapest]["violations"]
        )
        wins.append(win)
        gaps[name] = {
            "cheapest_classical": cheapest,
            "best_objective_classical": best_obj,
            "rl_cost_over_cheapest": round(
                rl_best["cost_total"] - cell[cheapest]["cost_total"], 4
            ),
            "rl_violations_minus_cheapest": round(
                rl_best["violations"] - cell[cheapest]["violations"], 1
            ),
            "rl_obj_over_best": round(
                rl_best["objective"]
                / max(cell[best_obj]["objective"], 1e-9), 4
            ),
            "rl_best_label": rl_best_label,
            "rl_wins_cost_at_leq_violations": win,
            "rl_wins_blended_objective": rl_best["objective"]
            < cell[best_obj]["objective"],
        }
        grid[name] = cell

    thr = _rollout_throughput_64(state.params, envcfg)

    # -- zero-shot generalization: the A=8-trained controller at A=64 ------
    # (ROADMAP generalization study, smoke scale: the shared row-wise
    # torso makes this a pure eval task — same params, 8x the rows, no
    # retraining; per-arch demand held at the training level)
    A64 = 64
    wl64 = replicate_pool(SERVING_POOL, A64, strict_frac=STRICT_FRAC)
    rps64 = MEAN_RPS * A64 / len(wl)
    zero_shot: Dict[str, dict] = {
        "train_pool_size": len(wl), "eval_pool_size": A64,
        "mean_rps": rps64, "grid": {},
    }
    for name in ("mmpp_bursts", "flash_anti"):
        sc = SCENARIO_ZOO[name]
        arrivals = sc.build(
            A64, seed=sc.seed + EVAL_SEED_OFFSET + 1,
            duration_s=EVAL_DURATION_S, mean_rps=rps64,
        )
        cell: Dict[str, dict] = {}
        for pol_name in ("reactive", "paragon"):
            res = simulate(arrivals, wl64, VECTOR_SCHEDULERS[pol_name]())
            cell[pol_name] = {
                **res.summary(),
                "objective": round(
                    _objective(res.summary(), res.total_requests), 4
                ),
            }
        res = simulate(arrivals, wl64, RLPoolPolicy(params=state.params,
                                                    seed=13))
        cell["rl_pool"] = {
            **res.summary(),
            "objective": round(_objective(res.summary(), res.total_requests), 4),
        }
        best = min(("reactive", "paragon"),
                   key=lambda p: cell[p]["objective"])
        cell["best_classical"] = best
        cell["rl_obj_over_best_classical"] = round(
            cell["rl_pool"]["objective"] / max(cell[best]["objective"], 1e-9), 4
        )
        zero_shot["grid"][name] = cell
    zs_ratios = [c["rl_obj_over_best_classical"]
                 for c in zero_shot["grid"].values()]
    zero_shot["median_obj_ratio"] = float(np.median(zs_ratios))

    fleet = _fleet_generalization(state)
    vhead = _variant_head_live(params_before, state.params, wl, catalog)

    n_wins = int(np.sum(wins))
    n_obj_wins = int(sum(g["rl_wins_blended_objective"] for g in gaps.values()))
    claims = {
        "variant_head_live": vhead,
        "evaluated_scenarios": len(grid),
        "classical_schedulers": list(CLASSICAL),
        "rl_wins_cost_at_leq_violations": n_wins,
        "rl_wins_blended_objective": n_obj_wins,
        "per_scenario_gap": gaps,
        "zero_shot": zero_shot,
        "fleet_scale": fleet,
        "explanation": (
            "A cost win means the trained pool controller undercuts the "
            "cheapest classical scheduler's raw cost on that scenario while "
            "violating no more requests.  Every gap and win field reports "
            "the controller's objective-best deployment mode (stochastic "
            "'rl_pool' vs greedy 'rl_pool_greedy'; 'rl_best_label' records "
            "which — under the 108-action space of PR 5's spot head the "
            "stochastic policy stays soft for this training budget, so "
            "greedy argmax is usually the stronger one).  When no cost "
            "win appears, the gap is structural, not a training failure: "
            "among on-demand schemes the raw-cost floor is reactive's "
            "ceil(ewma/throughput) fleet, and this simulator's burst "
            "premium makes *sustained* under-provisioning plus offload "
            "strictly costlier than reserving, so no controller can sit "
            "below that floor at equal violations — it can only choose "
            "where on the cost/violation frontier to sit.  The controller "
            "sits at the zero-violation end at a few percent cost premium "
            "('rl_cost_over_cheapest', 'rl_violations_minus_cheapest' "
            "quantify this per scenario) and wins the blended objective "
            "cost + {} x violations it was trained on against the best "
            "classical scheme on 'rl_wins_blended_objective' of the "
            "scenarios ('rl_obj_over_best' < 1).".format(PENALTY)
        ),
    }
    # training-curve summary (the full per-iteration stream is also on
    # disk at ``log_path`` as JSONL, one row per iteration)
    hist = state.history
    first, last = hist[0], hist[-1]
    curve = {
        "log_path": os.path.relpath(os.path.abspath(log_path)),
        "iterations": len(hist),
        "loss_first": first["loss_mean"],
        "loss_last": last["loss_mean"],
        "entropy_first": first["entropy_mean"],
        "entropy_last": last["entropy_mean"],
        "approx_kl_mean": float(np.mean([h["approx_kl"] for h in hist])),
        "approx_kl_max": float(np.max([h["approx_kl"] for h in hist])),
        "reward_first": first["rollout_reward"],
        "reward_last": last["rollout_reward"],
        "reward_best": state.best_reward,
        # trend over the curve's halves: positive means the second half
        # of training out-earned the first (scenario resampling makes
        # single-iteration rewards noisy)
        "reward_trend": float(
            np.mean([h["rollout_reward"] for h in hist[len(hist) // 2:]])
            - np.mean([h["rollout_reward"] for h in hist[:max(len(hist) // 2, 1)]])
        ),
    }
    payload = {
        "pool": SERVING_POOL,
        "mean_rps": MEAN_RPS,
        "train": {
            "iterations": iterations,
            "duration_s": TRAIN_DURATION_S,
            "penalty": PENALTY,
            "jax_rollouts": JAX_ROLLOUTS,
            "wall_s": round(train_wall, 2),
            "best_rollout_reward": state.best_reward,
            "curve": curve,
            "history": state.history,
        },
        "eval_duration_s": EVAL_DURATION_S,
        "grid": grid,
        "rollout_throughput_a64": thr,
        "claims": claims,
    }
    write_artifact("BENCH_rl_pool", payload, t0)

    registered = isinstance(VECTOR_SCHEDULERS.get("rl_pool"), type) and (
        VECTOR_SCHEDULERS["rl_pool"] is RLPoolPolicy
    )
    obj_ratios = [gaps[n]["rl_obj_over_best"] for n in gaps]
    rows: List[Row] = [
        ("rl_pool_registered", float(registered),
         "RL policy registered in VECTOR_SCHEDULERS", registered),
        ("scenarios_evaluated", float(len(grid)),
         "pool controller evaluated on >= 4 zoo scenarios vs all 6 "
         "classical vector schedulers", len(grid) >= 4),
        ("rl_wins_cost_at_leq_violations", float(n_wins),
         "RL cheaper than cheapest classical at <= violations on >= 1 "
         "scenario (gap reported in claims block otherwise)",
         n_wins >= 1 or (
             len(gaps) == len(grid)
             and all(np.isfinite(g["rl_cost_over_cheapest"])
                     and np.isfinite(g["rl_violations_minus_cheapest"])
                     for g in gaps.values())
         )),
        ("rl_wins_blended_objective", float(n_obj_wins),
         "RL beats the best classical scheme on the trained blended "
         "objective on >= 1 scenario (full runs; at BENCH_SMALL the "
         "few-iteration policy over the 108-action space only reports)",
         n_obj_wins >= 1 or BENCH_SMALL),
        ("rl_obj_over_best_median", float(np.median(obj_ratios)),
         "median blended-objective ratio vs best classical (reported)", True),
        ("variant_head_live_swaps",
         float(vhead["variant_swaps_stochastic"]),
         "the stochastic rl_pool deployment (the VECTOR_SCHEDULERS "
         "registry default) of the controller trained with the catalog "
         "attached (in-scan variant head live) exercises the swap "
         "pipeline on catalog-attached held-out evals; greedy-vs-greedy "
         "before/after blended-objective delta vs the committed "
         "variant-blind checkpoint recorded in claims.variant_head_live",
         vhead["variant_swaps_stochastic"] > 0),
        ("zero_shot_obj_ratio_a64", zero_shot["median_obj_ratio"],
         "A=8-trained controller evaluated zero-shot at A=64: median "
         "blended-objective ratio vs best classical (gap recorded in "
         "claims.zero_shot)",
         bool(np.isfinite(zs_ratios).all())),
        ("fleet_zoo_cells",
         float(len(SCENARIO_ZOO)),
         "full-zoo batched PPO: every study training iteration collects "
         "all S zoo scenarios in one [S, T, A] vmapped scan dispatch",
         all(c.get("full_zoo") for c in fleet["train"].values())
         and len(SCENARIO_ZOO) >= 4),
    ] + [
        (f"fleet_obj_ratio_a{A}", fleet["median_obj_ratio"][str(A)],
         f"full-zoo-trained A=8/16 controllers zero-shot at A={A} with "
         "variant catalog + spot head active: median blended-objective "
         "ratio vs best classical (gap recorded in claims.fleet_scale)",
         bool(np.isfinite(
             [c["rl_obj_over_best_classical"]
              for c in fleet["eval"][str(A)].values()]
         ).all()))
        for A in FLEET_EVAL_POOLS
    ] + [
        ("rollout_ticks_per_s_a64", thr["ticks_per_s"],
         "PoolServingEnv+policy rollout throughput at A=64", True),
        ("jax_rollout_speedup_a64",
         thr["jax_collector"]["speedup_vs_env_loop"],
         "batched in-scan rollout collector vs the step-wise env loop "
         "at A=64 (recorded in rollout_throughput_a64.jax_collector)",
         thr["jax_collector"]["speedup_vs_env_loop"] > 1.0),
        ("training_log_rows", float(curve["iterations"]),
         "per-iteration loss/entropy/KL curve streamed to "
         f"{log_name} and summarized in train.curve",
         curve["iterations"] == iterations
         and os.path.exists(log_path)
         and np.isfinite([curve["loss_last"], curve["entropy_last"],
                          curve["approx_kl_mean"]]).all()),
    ]
    return print_rows("rl", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
