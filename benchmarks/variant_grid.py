"""BENCH: the joint model x resource decision space — variant-aware
schedulers across the workload-scenario zoo.

Every stream carries a pool-wide accuracy SLO (``ACC_FLOOR``) and the
engine runs with a :class:`~repro.core.sim.VariantCatalog` over the
8-arch serving pool, so schedulers can trade accuracy against cost at
runtime (INFaaS / Cocktail: the decision prior work never makes
jointly with procurement).  Three points on the frontier per scenario:

  ``reactive``        — fixed-variant baseline: every arch pinned to its
                        base model; cheap procurement, but the accuracy
                        SLO is violated wherever the base model sits
                        below the floor.
  ``accuracy_floor``  — cheapest variant meeting each stream's floor
                        (the runtime form of the paper's least-cost
                        selection) on Paragon procurement.
  ``infaas_variant``  — upgrade-on-slack / downgrade-on-pressure: spends
                        idle capacity on accuracy, sheds accuracy under
                        queue pressure.

Artifact: ``BENCH_variant_grid.json``.

Claims:
  * both variant-aware schedulers are registered in VECTOR_SCHEDULERS
    (CI fails if they are ever dropped);
  * request flow AND accuracy mass conserve in every cell;
  * ``accuracy_floor`` strictly dominates fixed-variant ``reactive`` on
    cost at equal-or-better delivered accuracy on >= 3 zoo scenarios
    (and eliminates its accuracy-SLO violations);
  * ``infaas_variant`` actually exercises the swap pipeline and
    delivers more accuracy than the fixed baseline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, VariantCatalog, uniform_pool_workload
from repro.core.workloads import SCENARIO_ZOO

DURATION_S = 600 if BENCH_SMALL else 3600
MEAN_RPS = 200.0 if BENCH_SMALL else 400.0
#: pool-wide accuracy SLO: above the cheap tier (whisper/qwen/rwkv/
#: minicpm sit below it -> a fixed-variant fleet must violate), below
#: the premium tier (several candidates satisfy it -> a real choice)
ACC_FLOOR = 0.55
POLICIES = ("reactive", "paragon", "infaas_variant", "accuracy_floor")


def _run_one(arrivals: np.ndarray, wl, catalog, policy) -> tuple:
    sim = ServingSim(arrivals, wl, catalog=catalog)
    while not sim.done:
        sim.apply_pool(policy(sim.tick, sim.observe_pool()))
    return sim.res, sim.per_arch_counts()


def run() -> bool:
    t0 = time.perf_counter()
    wl = [
        dataclasses.replace(w, min_accuracy=ACC_FLOOR)
        for w in uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    ]
    catalog = VariantCatalog.for_workload(wl)
    payload: Dict[str, dict] = {
        "duration_s": DURATION_S,
        "mean_rps": MEAN_RPS,
        "accuracy_floor": ACC_FLOOR,
        "pool": SERVING_POOL,
        "variants_per_arch": {a: catalog.n_variants(a) for a in SERVING_POOL},
        "grid": {},
    }

    conserved = True
    dominated, infaas_swapped, infaas_more_accurate = [], [], []
    for name, sc in SCENARIO_ZOO.items():
        arrivals = sc.build(len(wl), duration_s=DURATION_S, mean_rps=MEAN_RPS)
        cell: Dict[str, dict] = {"scenario": sc.to_dict()}
        for pol_name in POLICIES:
            res, counts = _run_one(
                arrivals, wl, catalog, VECTOR_SCHEDULERS[pol_name]()
            )
            accounted = (
                counts["served_vm"] + counts["served_burst"] + counts["dropped"]
                + counts["expired_end"] + counts["queued"]
            )
            answered = (
                counts["served_vm"] + counts["served_burst"] + counts["dropped"]
            )
            ok = bool(
                np.allclose(counts["arrived"], accounted, atol=1e-6, rtol=1e-9)
                and np.isclose(float(counts["acc_weight"].sum()),
                               res.accuracy_weighted)
                and np.isclose(float(answered.sum()), res.accuracy_served)
            )
            conserved &= ok
            cell[pol_name] = {**res.summary(), "conserved": ok}
        r_fix, r_floor, r_inf = (
            cell["reactive"], cell["accuracy_floor"], cell["infaas_variant"]
        )
        dominated.append(
            r_floor["cost_total"] < r_fix["cost_total"]
            and r_floor["mean_accuracy"] >= r_fix["mean_accuracy"] - 1e-9
            and r_floor["acc_violation_rate"] <= r_fix["acc_violation_rate"]
        )
        infaas_swapped.append(r_inf["variant_swaps"] > 0)
        infaas_more_accurate.append(
            r_inf["mean_accuracy"] > r_fix["mean_accuracy"]
        )
        cell["accuracy_floor_dominates_reactive"] = dominated[-1]
        payload["grid"][name] = cell

    registered = all(
        name in VECTOR_SCHEDULERS for name in ("infaas_variant", "accuracy_floor")
    )
    n_dom = int(np.sum(dominated))
    rows: List[Row] = [
        ("variant_schedulers_registered", float(registered),
         "infaas_variant + accuracy_floor present in VECTOR_SCHEDULERS",
         registered),
        ("scenarios", float(len(payload["grid"])),
         "grid covers >= 4 zoo scenarios", len(payload["grid"]) >= 4),
        ("conserved_all", float(conserved),
         "request flow + accuracy mass conserve in every cell", conserved),
        ("accuracy_floor_dominates", float(n_dom),
         "accuracy_floor beats fixed-variant reactive on cost at >= equal "
         "accuracy and <= acc violations on >= 3 scenarios", n_dom >= 3),
        ("infaas_swaps_all_scenarios", float(np.sum(infaas_swapped)),
         "infaas_variant exercises the swap pipeline on every scenario",
         all(infaas_swapped)),
        ("infaas_more_accurate", float(np.sum(infaas_more_accurate)),
         "upgrade-on-slack delivers more accuracy than the fixed baseline "
         "on every scenario", all(infaas_more_accurate)),
    ]

    write_artifact("BENCH_variant_grid", payload)
    return print_rows("variant_grid", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
