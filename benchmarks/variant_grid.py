"""BENCH: the joint model x resource decision space — variant-aware
schedulers across the workload-scenario zoo, dispatched through the
jitted vmapped grid.

Every stream carries a pool-wide accuracy SLO (``ACC_FLOOR``) and the
engine runs with a :class:`~repro.core.sim.VariantCatalog` over the
8-arch serving pool, so schedulers can trade accuracy against cost at
runtime (INFaaS / Cocktail: the decision prior work never makes
jointly with procurement).  Four points on the frontier per scenario:

  ``reactive``        — fixed-variant baseline: every arch pinned to its
                        base model; cheap procurement, but the accuracy
                        SLO is violated wherever the base model sits
                        below the floor.
  ``paragon``         — the paper's class-aware scheme, also pinned.
  ``accuracy_floor``  — cheapest variant meeting each stream's floor
                        (the runtime form of the paper's least-cost
                        selection) on Paragon procurement.
  ``infaas_variant``  — upgrade-on-slack / downgrade-on-pressure: spends
                        idle capacity on accuracy, sheds accuracy under
                        queue pressure.

Since the variant axis lives inside the ``lax.scan`` (PR 9) the whole
zoo runs as ONE :func:`~repro.core.sim.jax_engine.run_grid` vmapped
dispatch per policy — the per-cell summaries come out of the jitted
scan, and a NumPy-oracle cell pins the dispatch against the reference
engine at 1e-6 before any claim is read off it.

Artifact: ``BENCH_variant_grid.json``.

Claims:
  * both variant-aware schedulers are registered in VECTOR_SCHEDULERS
    AND in the scan-side JAX_POLICIES registry (CI fails if either
    form is ever dropped — the fleet-speed path must not silently
    regress to NumPy-only);
  * one (scenario, policy) cell re-run through the NumPy engine matches
    the vmapped dispatch at 1e-6 with exact swap counts;
  * request flow AND accuracy mass conserve in every cell;
  * ``accuracy_floor`` strictly dominates fixed-variant ``reactive`` on
    cost at equal-or-better delivered accuracy on >= 3 zoo scenarios
    (and eliminates its accuracy-SLO violations);
  * ``infaas_variant`` actually exercises the swap pipeline and
    delivers more accuracy than the fixed baseline;
  * the variant-aware scan at A=64 runs >= 5x the NumPy tick loop —
    same process, min-over-repeats on both sides (report-only under
    BENCH_SMALL: CI boxes vary too much for an absolute-ratio gate).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import (
    ServingSim,
    VariantCatalog,
    replicate_pool,
    uniform_pool_workload,
)
from repro.core.workloads import SCENARIO_ZOO

DURATION_S = 600 if BENCH_SMALL else 3600
MEAN_RPS = 200.0 if BENCH_SMALL else 400.0
#: pool-wide accuracy SLO: above the cheap tier (whisper/qwen/rwkv/
#: minicpm sit below it -> a fixed-variant fleet must violate), below
#: the premium tier (several candidates satisfy it -> a real choice)
ACC_FLOOR = 0.55
POLICIES = ("reactive", "paragon", "infaas_variant", "accuracy_floor")
#: the cell the NumPy oracle re-runs (the scenario with the most swap
#: pressure under the slack-driven scheduler)
ORACLE_CELL = ("trending_hotswap", "infaas_variant")
# speedup section: variant-aware scan vs the NumPy tick loop at the
# INFaaS pool scale.  Full scan length always — a short scan
# under-amortizes dispatch overhead and misstates the claim.
SPEEDUP_ARCHS = 64
SPEEDUP_TICKS = 3600
SPEEDUP_REPEATS = 2 if BENCH_SMALL else 3
SPEEDUP_FLOOR = 5.0


def _numpy_run(arrivals: np.ndarray, wl, catalog, pol_name: str):
    sim = ServingSim(arrivals, wl, catalog=catalog)
    policy = VECTOR_SCHEDULERS[pol_name]()
    while not sim.done:
        sim.apply_pool(policy(sim.tick, sim.observe_pool()))
    return sim


def _cell_conserves(cell: dict, acc_lo: np.ndarray, acc_hi: np.ndarray) -> bool:
    """Flow + accuracy-mass conservation from one grid cell's per-arch
    arrays: admitted mass is fully accounted, and the delivered-accuracy
    mass sits inside the catalog's per-arch accuracy envelope (the scan
    must bill accuracy at an actually-deployable variant, every tick)."""
    pa = cell["per_arch"]
    accounted = (
        pa["served_vm"] + pa["served_burst"] + pa["dropped"]
        + pa["expired_end"] + pa["queued"]
    )
    answered = pa["served_vm"] + pa["served_burst"] + pa["dropped"]
    return bool(
        np.allclose(pa["arrived"], accounted, atol=1e-6, rtol=1e-9)
        and (pa["acc_weight"] <= answered * acc_hi + 1e-6).all()
        and (pa["acc_weight"] >= answered * acc_lo - 1e-6).all()
        and (pa["acc_violations"] <= answered + 1e-6).all()
    )


def _speedup_bench() -> dict:
    """Variant-aware scan vs NumPy tick loop, A=64, same process.

    Min over repeats on BOTH sides (single-core boxes jitter +-50%);
    the warm-scan wall isolates the jitted dispatch — host-side input
    build and compile are reported separately, exactly like the
    ``sim_throughput`` scan rows."""
    import jax
    from jax.experimental import enable_x64

    from repro.core.sim import jax_engine as je

    wl = [
        dataclasses.replace(w, min_accuracy=ACC_FLOOR)
        for w in replicate_pool(SERVING_POOL, SPEEDUP_ARCHS,
                                strict_frac=STRICT_FRAC)
    ]
    catalog = VariantCatalog.for_workload(wl)
    arr = SCENARIO_ZOO["trending_hotswap"].build(
        SPEEDUP_ARCHS, duration_s=SPEEDUP_TICKS, mean_rps=MEAN_RPS
    )

    np_wall = float("inf")
    for _ in range(2):
        t = time.perf_counter()
        sim = _numpy_run(arr, wl, catalog, "infaas_variant")
        np_wall = min(np_wall, time.perf_counter() - t)
    res_np = sim.res

    pol = je.JAX_POLICIES["infaas_variant"]
    statics, state0, xs = je.build_sim_inputs(
        arr, wl, catalog=catalog, needs_stats=pol.needs_stats
    )
    statics["policy"] = pol.default_params()
    runner = je._get_runner("infaas_variant", variants=True)
    with enable_x64():
        t = time.perf_counter()
        out = jax.block_until_ready(runner(statics, state0, xs))
        first = time.perf_counter() - t
        scan_wall = float("inf")
        for _ in range(SPEEDUP_REPEATS):
            t = time.perf_counter()
            out = jax.block_until_ready(runner(statics, state0, xs))
            scan_wall = min(scan_wall, time.perf_counter() - t)
    res_jx = je._assemble(
        jax.tree.map(np.asarray, out), np.asarray(arr, dtype=np.float64)
    )["summary"]
    # the timed pair IS a differential sample: both engines must agree
    # before the ratio means anything
    assert abs(res_jx["cost_total"] - res_np.cost_total) <= 1e-6 * max(
        abs(res_np.cost_total), 1.0
    ), "engines drifted on the speedup pair"
    assert res_jx["variant_swaps"] == res_np.variant_swaps, "swap-count drift"
    return {
        "archs": SPEEDUP_ARCHS,
        "ticks": SPEEDUP_TICKS,
        "policy": "infaas_variant",
        "scenario": "trending_hotswap",
        "variant_swaps": int(res_np.variant_swaps),
        "numpy_wall_s": np_wall,
        "numpy_ticks_per_s": SPEEDUP_TICKS / np_wall,
        "jax_first_s": first,               # compile + run
        "jax_scan_s": scan_wall,
        "jax_ticks_per_s": SPEEDUP_TICKS / scan_wall,
        "speedup": np_wall / scan_wall,
    }


def run() -> bool:
    from repro.core.sim import jax_engine as je

    t0 = time.perf_counter()
    wl = [
        dataclasses.replace(w, min_accuracy=ACC_FLOOR)
        for w in uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    ]
    catalog = VariantCatalog.for_workload(wl)
    # per-arch accuracy envelope for the mass-conservation check
    acc_lo = np.array([min(v.accuracy for v in catalog.variants(w.arch))
                       for w in wl])
    acc_hi = np.array([max(v.accuracy for v in catalog.variants(w.arch))
                       for w in wl])

    scenarios = list(SCENARIO_ZOO)
    arrs = np.stack([
        SCENARIO_ZOO[name].build(len(wl), duration_s=DURATION_S,
                                 mean_rps=MEAN_RPS)
        for name in scenarios
    ])

    payload: Dict[str, dict] = {
        "duration_s": DURATION_S,
        "mean_rps": MEAN_RPS,
        "accuracy_floor": ACC_FLOOR,
        "pool": SERVING_POOL,
        "variants_per_arch": {a: catalog.n_variants(a) for a in SERVING_POOL},
        "grid": {name: {"scenario": SCENARIO_ZOO[name].to_dict()}
                 for name in scenarios},
        "dispatch": {},
    }

    # -- the whole zoo per policy, ONE vmapped dispatch each ----------
    conserved = True
    for pol_name in POLICIES:
        t = time.perf_counter()
        cells = je.run_grid(arrs, wl, pol_name, catalog=catalog)
        payload["dispatch"][pol_name] = {
            "cells": len(cells), "wall_s": time.perf_counter() - t,
        }
        for name, cell in zip(scenarios, cells):
            ok = _cell_conserves(cell, acc_lo, acc_hi)
            conserved &= ok
            payload["grid"][name][pol_name] = {
                **cell["summary"], "conserved": ok,
            }

    # -- NumPy-oracle cell: the dispatch's numbers are the engine's ---
    oracle_scenario, oracle_policy = ORACLE_CELL
    sim = _numpy_run(arrs[scenarios.index(oracle_scenario)], wl, catalog,
                     oracle_policy)
    np_summary = sim.res.summary()
    jx_summary = payload["grid"][oracle_scenario][oracle_policy]
    oracle_ok = all(
        np.isclose(jx_summary[k], v, rtol=1e-6, atol=1e-6)
        for k, v in np_summary.items()
    ) and jx_summary["variant_swaps"] == np_summary["variant_swaps"]
    payload["oracle_cell"] = {
        "scenario": oracle_scenario, "policy": oracle_policy,
        "numpy": np_summary, "ok": oracle_ok,
    }

    # -- frontier claims off the per-cell summaries -------------------
    dominated, infaas_swapped, infaas_more_accurate = [], [], []
    for name in scenarios:
        cell = payload["grid"][name]
        r_fix, r_floor, r_inf = (
            cell["reactive"], cell["accuracy_floor"], cell["infaas_variant"]
        )
        dominated.append(
            r_floor["cost_total"] < r_fix["cost_total"]
            and r_floor["mean_accuracy"] >= r_fix["mean_accuracy"] - 1e-9
            and r_floor["acc_violation_rate"] <= r_fix["acc_violation_rate"]
        )
        infaas_swapped.append(r_inf["variant_swaps"] > 0)
        infaas_more_accurate.append(
            r_inf["mean_accuracy"] > r_fix["mean_accuracy"]
        )
        cell["accuracy_floor_dominates_reactive"] = dominated[-1]

    payload["speedup_a64"] = sp = _speedup_bench()

    registered = all(
        name in VECTOR_SCHEDULERS and name in je.JAX_POLICIES
        for name in ("infaas_variant", "accuracy_floor")
    )
    n_dom = int(np.sum(dominated))
    rows: List[Row] = [
        ("variant_schedulers_registered", float(registered),
         "infaas_variant + accuracy_floor present in VECTOR_SCHEDULERS "
         "and JAX_POLICIES (the scan-side registry)", registered),
        ("scenarios", float(len(scenarios)),
         "grid covers >= 4 zoo scenarios", len(scenarios) >= 4),
        ("oracle_cell_parity", float(oracle_ok),
         "vmapped-dispatch cell == NumPy engine at 1e-6, exact swaps",
         oracle_ok),
        ("conserved_all", float(conserved),
         "request flow + accuracy mass conserve in every cell", conserved),
        ("accuracy_floor_dominates", float(n_dom),
         "accuracy_floor beats fixed-variant reactive on cost at >= equal "
         "accuracy and <= acc violations on >= 3 scenarios", n_dom >= 3),
        ("infaas_swaps_all_scenarios", float(np.sum(infaas_swapped)),
         "infaas_variant exercises the swap pipeline on every scenario",
         all(infaas_swapped)),
        ("infaas_more_accurate", float(np.sum(infaas_more_accurate)),
         "upgrade-on-slack delivers more accuracy than the fixed baseline "
         "on every scenario", all(infaas_more_accurate)),
        ("variant_scan_speedup_a64", sp["speedup"],
         f"variant-aware jitted scan >= {SPEEDUP_FLOOR:g}x the NumPy tick "
         f"loop at A={SPEEDUP_ARCHS} ({SPEEDUP_TICKS} ticks, same process, "
         "min-over-repeats; report-only under BENCH_SMALL)",
         BENCH_SMALL or sp["speedup"] >= SPEEDUP_FLOOR),
    ]

    # persist the enforced claims into the artifact itself (same
    # convention as BENCH_tier_portfolio) so the committed JSON records
    # what was asserted, not just the measured inputs
    payload["claims"] = {
        metric: {"value": value, "claim": claim, "ok": bool(ok)}
        for metric, value, claim, ok in rows
    }
    write_artifact("BENCH_variant_grid", payload, t0)
    return print_rows("variant_grid", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
