"""Fig 8: the burst-instance sizing knob (the Lambda memory-allocation
analog).  More chips -> lower latency (sublinearly, collectives + Amdahl)
-> higher $/request; past the knee latency stops improving but cost keeps
rising — exactly the squeezenet@2GB footnote."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, print_rows, write_artifact
from repro.core.profiles import STANDARD, ModelProfile, get_profile
from repro.core.hardware import PRICING

MODELS = ["qwen1.5-0.5b", "llama3-8b", "qwen2-72b"]
MULTS = (1, 2, 4, 8, 16)


def run() -> bool:
    t0 = time.perf_counter()
    table = {}
    rows: List[Row] = []
    for arch in MODELS:
        base = get_profile(arch)
        base_chips = ModelProfile(base.cfg, 1).min_chips
        entries = []
        for m in MULTS:
            p = ModelProfile(base.cfg, base_chips * m)
            lat = p.request_latency(STANDARD, 1)
            cost = (
                lat * p.chips * PRICING.reserved_chip_s * PRICING.burst_premium
                * 1e6  # $/1M requests if billed at raw busy time
            )
            entries.append({"chips": p.chips, "latency_s": lat, "cost_1m": cost})
        table[arch] = entries

        lats = [e["latency_s"] for e in entries]
        costs = [e["cost_1m"] for e in entries]
        monotone_lat = all(a >= b - 1e-9 for a, b in zip(lats, lats[1:]))
        cost_up = costs[-1] > costs[0]
        # knee: the last doubling buys < 15% latency, the first > 25%
        first_gain = 1 - lats[1] / lats[0]
        last_gain = 1 - lats[-1] / lats[-2]
        rows.append((
            f"{arch}_latency_falls", first_gain,
            "latency falls with slice size",
            monotone_lat and first_gain > 0.2,
        ))
        rows.append((
            f"{arch}_knee", last_gain,
            "diminishing returns past the knee, cost keeps rising",
            last_gain < first_gain and cost_up,
        ))
    write_artifact("fig8_burst_sizing", table)
    return print_rows("fig8", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
