"""BENCH: every scheduler across the workload-scenario matrix.

Runs each named preset in :data:`repro.core.workloads.SCENARIO_ZOO`
(shared pool trace, phase-shifted diurnals, correlated / anti-correlated
flash crowds, MMPP bursts, trending-model hotswap) against every
vectorized scheduler over the 8-arch serving pool, through the engine's
per-arch arrival path.  This is the evaluation surface the paper's
self-managed claim needs: schemes tuned on one shared trace meet load
shapes static share-scaling cannot express.

Artifact: ``BENCH_scenario_grid.json`` — per (scenario, scheduler)
summaries plus per-arch violation spread.

Claims:
  * grid covers >= 4 scenarios x >= 4 schedulers;
  * every run conserves requests (arrivals == served + queued at end);
  * the paper's class-aware scheme stays cheaper than peak-provisioning
    exascale on every scenario (Observation 4: provisioning for the peak
    of a bursty stream is the expensive way to meet SLOs).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, uniform_pool_workload
from repro.core.traces import peak_to_median
from repro.core.workloads import SCENARIO_ZOO

DURATION_S = 600 if BENCH_SMALL else 3600
MEAN_RPS = 120.0 if BENCH_SMALL else 400.0


def _run_one(arrivals: np.ndarray, wl, policy) -> tuple:
    sim = ServingSim(arrivals, wl)
    while not sim.done:
        sim.apply_pool(policy(sim.tick, sim.observe_pool()))
    counts = sim.per_arch_counts()
    return sim.res, counts


def run() -> bool:
    t0 = time.perf_counter()
    wl = uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    payload: Dict[str, dict] = {
        "duration_s": DURATION_S,
        "mean_rps": MEAN_RPS,
        "pool": SERVING_POOL,
        "grid": {},
    }

    conserved = True
    paragon_cheaper = True
    for name, sc in SCENARIO_ZOO.items():
        arrivals = sc.build(len(wl), duration_s=DURATION_S, mean_rps=MEAN_RPS)
        p2m = peak_to_median(arrivals, axis=1)   # Fig-7 statistic per arch
        cell: Dict[str, dict] = {
            "scenario": sc.to_dict(),
            "peak_to_median_arch": [round(float(v), 3) for v in p2m],
        }
        for pol_name in sorted(VECTOR_SCHEDULERS):
            pol = VECTOR_SCHEDULERS[pol_name]()
            res, counts = _run_one(arrivals, wl, pol)
            accounted = (
                counts["served_vm"] + counts["served_burst"] + counts["dropped"]
                + counts["expired_end"] + counts["queued"]
            )
            ok = bool(
                np.allclose(counts["arrived"], accounted, atol=1e-6, rtol=1e-9)
            )
            conserved &= ok
            viol_arch = counts["violations"] / np.maximum(counts["arrived"], 1e-9)
            cell[pol_name] = {
                **res.summary(),
                "conserved": ok,
                "violation_rate_arch_max": float(viol_arch.max()),
                "violation_rate_arch_spread": float(viol_arch.max() - viol_arch.min()),
            }
            if hasattr(pol, "trained"):
                # a learned policy running from fallback (untrained) weights
                # must be visible in the artifact, not just functional
                cell[pol_name]["trained"] = bool(pol.trained)
        paragon_cheaper &= (
            cell["paragon"]["cost_total"] <= cell["exascale"]["cost_total"]
        )
        payload["grid"][name] = cell

    n_sc = len(payload["grid"])
    n_pol = len(VECTOR_SCHEDULERS)
    rows: List[Row] = [
        ("scenarios", n_sc, "grid covers >= 4 scenarios", n_sc >= 4),
        ("schedulers", n_pol, "grid covers >= 4 (vector) schedulers", n_pol >= 4),
        ("conserved_all", float(conserved),
         "arrivals == served + queued for every cell", conserved),
        ("paragon_cheaper_than_exascale", float(paragon_cheaper),
         "class-aware offload beats peak provisioning on cost, all scenarios",
         paragon_cheaper),
    ]

    write_artifact("BENCH_scenario_grid", payload)
    return print_rows("scenario_grid", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
