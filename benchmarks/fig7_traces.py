"""Fig 7: peak-to-median ratios of the four trace twins."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, print_rows, write_artifact
from repro.core.traces import trace_stats


def run() -> bool:
    t0 = time.perf_counter()
    stats = trace_stats()
    rows: List[Row] = []
    rows.append((
        "wiki_peak_to_median", stats["wiki"]["peak_to_median"],
        "paper: wiki low (~1.3) -> mixed will not pay off",
        stats["wiki"]["peak_to_median"] < 1.6,
    ))
    for name in ("berkeley", "wits", "twitter"):
        v = stats[name]["peak_to_median"]
        rows.append((
            f"{name}_peak_to_median", v,
            "paper: >50% peak-over-median (ratio > 2)",
            v > 2.0,
        ))
    write_artifact("fig7_traces", stats)
    return print_rows("fig7", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
