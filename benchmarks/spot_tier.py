"""Beyond-paper (paper §VI "Limitations"): the SPOT instance tier.

The paper lists spot/burstable instances as future work.  We implement a
spot tier (0.3x price, Poisson reclaim ~1/30 min/instance, same
provisioning latency) and a spot-aware Paragon: on-demand floor sized for
the strict class, preemptible spot for the base load, class-aware burst
for reclaim dips.

Evaluated on a FLEET-SCALE workload (two archs, 500 req/s) — the spot win
needs fleets of >> 1 instance per arch; at 1-2 instances the on-demand
floor quantizes the saving away (reported separately).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import PRICING_X, Row, print_rows, write_artifact
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import ArchLoad, simulate
from repro.core.traces import get_trace

WORKLOAD = [ArchLoad("llama3-8b", 0.6, 0.25), ArchLoad("minicpm-2b", 0.4, 0.25)]
MEAN_RPS = 500.0


def run() -> bool:
    t0 = time.perf_counter()
    payload = {}
    rows: List[Row] = []
    for trace_name in ("berkeley", "wiki"):
        trace = get_trace(trace_name, 3600, mean_rps=MEAN_RPS)
        res = {
            n: simulate(trace, WORKLOAD, SCHEDULERS[n](), pricing=PRICING_X)
            for n in ("reactive", "paragon", "spot_paragon")
        }
        payload[trace_name] = {n: r.summary() for n, r in res.items()}
        saving = 1 - res["spot_paragon"].cost_total / res["paragon"].cost_total
        rows.append((
            f"{trace_name}_spot_saving_vs_paragon", saving,
            "spot tier >= 35% cheaper at fleet scale",
            saving >= 0.35,
        ))
        rows.append((
            f"{trace_name}_spot_strict_violations",
            res["spot_paragon"].violations_strict,
            "strict SLOs survive preemptions (on-demand floor)",
            res["spot_paragon"].violations_strict == 0,
        ))
        rows.append((
            f"{trace_name}_preemptions", res["spot_paragon"].preemptions,
            "preemption risk is real (reclaims occurred)",
            res["spot_paragon"].preemptions > 0,
        ))
    write_artifact("spot_tier", payload)
    return print_rows("spot", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
