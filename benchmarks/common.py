"""Shared benchmark configuration + artifact helpers.

Every benchmark writes a JSON artifact under ``artifacts/benchmarks/`` and
returns a list of (metric, value, claim, ok) rows that ``run.py`` prints
as CSV and aggregates into the exit status.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.hardware import PRICING

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "benchmarks")

# The serving-workload pool: every arch a burst pool could plausibly host
# (kimi-k2 / qwen2-72b are reserved-only paper-table members; they appear
# in the fig2/fig4/fig8 characterization but not in the trace simulations).
SERVING_POOL = [
    "llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
    "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
    "phi3.5-moe-42b-a6.6b",
]

# experiment pricing: burst premium at the top of the Lambda/EC2 band
PRICING_X = dataclasses.replace(PRICING, burst_premium=8.0)

MEAN_RPS = 400.0
DURATION_S = 3600
STRICT_FRAC = 0.25

# BENCH_SMALL=1 shrinks trace lengths / pool sizes so CI can smoke-run
# benchmark entrypoints in seconds (claims still evaluated, just on the
# small configuration)
BENCH_SMALL = os.environ.get("BENCH_SMALL", "") == "1"

Row = Tuple[str, float, str, bool]


def write_artifact(name: str, payload: Any) -> str:
    # small smoke runs must not clobber the committed full-run artifacts
    if BENCH_SMALL:
        name = f"{name}_small"
    os.makedirs(os.path.abspath(ARTIFACTS), exist_ok=True)
    path = os.path.join(os.path.abspath(ARTIFACTS), f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def print_rows(bench: str, rows: List[Row], t0: float) -> bool:
    ok_all = True
    for metric, value, claim, ok in rows:
        ok_all &= ok
        print(f"{bench},{metric},{value:.6g},{claim},{'OK' if ok else 'FAIL'}")
    print(f"{bench},_wall_s,{time.perf_counter() - t0:.2f},,OK")
    return ok_all
