"""Shared benchmark configuration + artifact helpers.

Every benchmark writes a JSON artifact under ``artifacts/benchmarks/`` and
returns a list of (metric, value, claim, ok) rows that ``run.py`` prints
as CSV and aggregates into the exit status.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.hardware import PRICING

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "benchmarks")

# The serving-workload pool: every arch a burst pool could plausibly host
# (kimi-k2 / qwen2-72b are reserved-only paper-table members; they appear
# in the fig2/fig4/fig8 characterization but not in the trace simulations).
SERVING_POOL = [
    "llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
    "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
    "phi3.5-moe-42b-a6.6b",
]

# experiment pricing: burst premium at the top of the Lambda/EC2 band
PRICING_X = dataclasses.replace(PRICING, burst_premium=8.0)

MEAN_RPS = 400.0
DURATION_S = 3600
STRICT_FRAC = 0.25

# BENCH_SMALL=1 shrinks trace lengths / pool sizes so CI can smoke-run
# benchmark entrypoints in seconds (claims still evaluated, just on the
# small configuration)
BENCH_SMALL = os.environ.get("BENCH_SMALL", "") == "1"

Row = Tuple[str, float, str, bool]


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        )
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except Exception:
        return "unknown"


def run_provenance(t0: Optional[float] = None) -> Dict[str, Any]:
    """Who/what/where stamp attached to every benchmark artifact.

    Records enough to reproduce or discount a number later: the exact
    commit, the numpy/jax versions the run saw, the platform, whether it
    was a BENCH_SMALL smoke, and (if ``t0`` from ``time.perf_counter()``
    is given) the wall time of the producing run.
    """
    import numpy as np
    prov: Dict[str, Any] = {
        "git_sha": _git_sha(),
        "python": platform.python_version(),
        "numpy": np.__version__,
        "platform": platform.platform(),
        "bench_small": BENCH_SMALL,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
    try:
        import jax
        prov["jax"] = jax.__version__
        prov["jax_backend"] = jax.default_backend()
    except Exception:
        prov["jax"] = None
    if t0 is not None:
        prov["wall_s"] = round(time.perf_counter() - t0, 3)
    return prov


def write_artifact(name: str, payload: Any, t0: Optional[float] = None) -> str:
    # small smoke runs must not clobber the committed full-run artifacts
    if BENCH_SMALL:
        name = f"{name}_small"
    if isinstance(payload, dict) and "provenance" not in payload:
        payload = {**payload, "provenance": run_provenance(t0)}
    os.makedirs(os.path.abspath(ARTIFACTS), exist_ok=True)
    path = os.path.join(os.path.abspath(ARTIFACTS), f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def print_rows(bench: str, rows: List[Row], t0: float) -> bool:
    ok_all = True
    for metric, value, claim, ok in rows:
        ok_all &= ok
        print(f"{bench},{metric},{value:.6g},{claim},{'OK' if ok else 'FAIL'}")
    print(f"{bench},_wall_s,{time.perf_counter() - t0:.2f},,OK")
    return ok_all
