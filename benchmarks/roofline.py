"""Roofline analysis (deliverable g): three terms per (arch x shape),
derived from the dry-run's compiled artifacts.

``compiled.cost_analysis()`` reports the PER-DEVICE post-SPMD module, so:

  compute_term    = flops / (peak_flops_per_chip * MFU-free)   [s]
  memory_term     = bytes_accessed / hbm_bw_per_chip           [s]
  collective_term = collective_bytes / ici_bw_per_chip         [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(2 usable links per transfer direction assumed -> 100 GB/s effective).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training;
2*N*D for inference steps.  The ratio MODEL_FLOPS / (flops * chips)
flags remat/redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import List, Optional

from benchmarks.common import Row, print_rows, write_artifact
from repro.configs import INPUT_SHAPES, get_config

PEAK = 197e12
HBM = 819e9
ICI = 100e9          # 2 links x ~50 GB/s usable per exchange

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.params_active
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyse(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    chips = rec["n_devices"]
    flops = rec["flops"]
    if flops < 0:
        return None
    compute = flops / PEAK
    memory = rec["bytes_accessed"] / HBM
    coll = rec["collectives"]["total"] / ICI
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops * chips, 1.0)
    bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "x".join(map(str, rec["mesh"])),
        "chips": chips,
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "collective_detail": rec["collectives"],
    }


def load_table(pod: str = "pod1") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{pod}.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse(rec)
        if row:
            out.append(row)
    return out


def render_markdown(table: List[dict]) -> str:
    lines = [
        "| arch | shape | chips | compute s | memory s | collective s | "
        "dominant | useful-FLOPs ratio |",
        "|---|---|---:|---:|---:|---:|---|---:|",
    ]
    for r in sorted(table, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(lines)


def run() -> bool:
    t0 = time.perf_counter()
    table = load_table("pod1")
    rows: List[Row] = []
    if not table:
        print("roofline,_skipped,0,run launch/dryrun.py --all first,OK")
        return True
    rows.append((
        "combos_analysed", len(table),
        "all 40 (arch x shape) combos have roofline terms",
        len(table) >= 40,
    ))
    # structural expectations
    decode = [r for r in table if r["shape"] in ("decode_32k", "long_500k")]
    mem_bound = sum(r["dominant"] in ("memory", "collective") for r in decode)
    rows.append((
        "decode_memory_or_coll_bound", mem_bound / max(len(decode), 1),
        "decode shapes are never compute-bound (roofline sanity)",
        all(r["dominant"] != "compute" for r in decode),
    ))
    for r in table:
        print(
            f"roofline_row,{r['arch']},{r['shape']},{r['chips']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
            f"{r['dominant']},{r['useful_flops_ratio']:.3f}"
        )
    write_artifact("roofline_table", table)
    md_path = os.path.join(DRYRUN_DIR, "..", "roofline.md")
    with open(os.path.abspath(md_path), "w") as f:
        f.write(render_markdown(table) + "\n")
    return print_rows("roofline", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
