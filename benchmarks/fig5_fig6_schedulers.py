"""Fig 5 + Fig 6: autoscaling schemes under trace-driven dynamic load.

Fig 5 — util_aware / exascale over-provision 20-30% more VM capacity than
        the reactive baseline.
Fig 6 — their cost is correspondingly higher; mixed procurement holds
        cost near reactive while slashing SLO violations.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import (
    DURATION_S,
    MEAN_RPS,
    PRICING_X,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import simulate, uniform_pool_workload
from repro.core.traces import TRACES, get_trace


def run() -> bool:
    t0 = time.perf_counter()
    wl = uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    results: Dict[str, Dict[str, dict]] = {}
    for trace_name in TRACES:
        trace = get_trace(trace_name, DURATION_S, mean_rps=MEAN_RPS)
        results[trace_name] = {}
        for sched, cls in SCHEDULERS.items():
            r = simulate(trace, wl, cls(), pricing=PRICING_X)
            results[trace_name][sched] = {
                **r.summary(),
                "chip_seconds": r.chip_seconds,
                "violations": r.violations,
            }

    rows: List[Row] = []
    dynamic = [t for t in TRACES if t != "wiki"]

    # Fig 5: over-provisioned capacity vs reactive on dynamic traces
    for name in ("util_aware", "exascale"):
        ratios = [
            results[t][name]["chip_seconds"] / results[t]["reactive"]["chip_seconds"]
            for t in dynamic
        ]
        mean_over = sum(ratios) / len(ratios) - 1.0
        rows.append((
            f"fig5_{name}_overprovision", mean_over,
            "paper: 20-30% over-provisioned VMs (band 10-65%)",
            0.10 < mean_over < 0.65,
        ))

    # Fig 6: cost normalized to reactive + SLO violations
    for t in TRACES:
        for name in SCHEDULERS:
            c = results[t][name]["cost_total"] / results[t]["reactive"]["cost_total"]
            results[t][name]["cost_vs_reactive"] = c

    mixed_cost = max(results[t]["mixed"]["cost_vs_reactive"] for t in dynamic)
    rows.append((
        "fig6_mixed_cost_vs_reactive", mixed_cost,
        "mixed stays within ~25% of reactive cost",
        mixed_cost < 1.30,
    ))
    viol_red = min(
        1 - results[t]["mixed"]["violation_rate"]
        / max(results[t]["reactive"]["violation_rate"], 1e-9)
        for t in dynamic
    )
    rows.append((
        "fig6_mixed_violation_reduction", viol_red,
        "paper: mixed cuts SLO violations by >= 60%",
        viol_red >= 0.60,
    ))
    cheaper_than_spares = all(
        results[t]["mixed"]["cost_total"] < results[t]["util_aware"]["cost_total"]
        for t in dynamic
    )
    rows.append((
        "fig6_mixed_beats_overprovisioning", 1.0,
        "mixed cheaper than holding spare VMs on dynamic traces",
        cheaper_than_spares,
    ))

    # Observation 4 via Fig 6: wiki (peak/median ~1.3) gains nothing
    wiki_burst_frac = (
        results["wiki"]["mixed"]["served_burst"]
        / max(results["wiki"]["mixed"]["served_vm"], 1.0)
    )
    rows.append((
        "fig6_wiki_burst_fraction", wiki_burst_frac,
        "flat trace -> mixed offloads ~nothing (Observation 4)",
        wiki_burst_frac < 0.02,
    ))

    write_artifact("fig5_fig6_schedulers", results)
    return print_rows("fig5_fig6", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
