"""BENCH: the tier portfolio across the scenario zoo.

The paper's core procurement argument is that cost-effective serving
must exploit the cloud's "confounding array of resource types".  This
benchmark runs every zoo scenario over the 8-arch serving pool at FLEET
SCALE (per-arch fleets of many instances — at 1-2 instances the
on-demand floor quantizes any tier split away) and compares:

  reactive      — all-reserved demand tracking (the paper baseline)
  spot_paragon  — on-demand floor + preemptible spot base (§VI)
  portfolio     — the full tier portfolio: reserved floor, remote-region
                  relaxed base, harvest VMs split by reclaim risk under
                  the provider ceiling, spot churn buffer, class-aware
                  burst offload
  rl_pool       — the trained pool controller, whose factored action
                  space now carries a spot head (grow / hold / shrink
                  the preemptible fleet, offsetting the reserved rule)

Artifact: ``BENCH_tier_portfolio.json`` — per (scenario, scheme)
summaries with the PER-TIER COST DECOMPOSITION (reserved / spot /
harvest / remote / burst — asserted to sum to the ledger total in every
cell), preemption counts, and a claims block.

Claims:
  * ``portfolio`` and ``rl_pool`` stay registered in
    ``VECTOR_SCHEDULERS`` (the bench-smoke CI job fails otherwise);
  * the per-tier decomposition sums to the ledger's cost_total in every
    cell;
  * ``portfolio`` beats reserved-only ``reactive`` on the blended
    cost + violation objective on >= 5 of the 7 zoo scenarios, engaging
    the harvest tier on every one of them;
  * the trained ``rl_pool`` (spot head active) beats ``reactive`` on
    the blended objective on >= 5 of 7 (reported, not enforced, when
    only untrained fallback weights are available).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.rl import RLPoolPolicy
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import simulate, uniform_pool_workload
from repro.core.workloads import SCENARIO_ZOO

PENALTY = 0.02                     # $ per violated request (blended objective)
#: fleet scale: ~150 req/s per arch -> multi-instance fleets everywhere
#: (the small config keeps fleet scale but shortens the horizon: at a
#: few hundred req/s the 1-instance floor quantizes the tier split away,
#: and under ~15 min the provisioning-lag transient dominates)
MEAN_RPS = 1200.0
DURATION_S = 900 if BENCH_SMALL else 3600
EVAL_SEED_OFFSET = 777             # held-out realizations of each scenario
SCHEMES = ("reactive", "spot_paragon", "portfolio", "rl_pool",
           "rl_pool_greedy")
TIER_KEYS = ("cost_reserved", "cost_spot", "cost_burst", "cost_harvest",
             "cost_remote")


def _objective(res) -> float:
    return res.cost_total + PENALTY * res.violations


def run() -> bool:
    t0 = time.perf_counter()
    wl = uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    payload: Dict[str, dict] = {
        "pool": SERVING_POOL,
        "mean_rps": MEAN_RPS,
        "duration_s": DURATION_S,
        "penalty": PENALTY,
        "grid": {},
    }

    decomposed = True
    harvest_used = 0
    rl_trained = True
    port_wins, rl_wins = [], []
    for name, sc in SCENARIO_ZOO.items():
        arrivals = sc.build(
            len(wl), seed=sc.seed + EVAL_SEED_OFFSET,
            duration_s=DURATION_S, mean_rps=MEAN_RPS,
        )
        cell: Dict[str, dict] = {"scenario": sc.to_dict()}
        for pol_name in SCHEMES:
            if pol_name == "rl_pool_greedy":
                pol = RLPoolPolicy(greedy=True)
            else:
                pol = VECTOR_SCHEDULERS[pol_name]()
            res = simulate(arrivals, wl, pol)
            s = res.summary()
            tiers = {k: s.get(k, 0.0) for k in TIER_KEYS}
            tier_sum = sum(tiers.values())
            ok = abs(tier_sum - s["cost_total"]) <= 1e-3 + 1e-6 * s["cost_total"]
            decomposed &= ok
            cell[pol_name] = {
                **s,
                "objective": round(_objective(res), 4),
                "violations": round(res.violations, 1),
                "tier_decomposition": tiers,
                "tier_sum_matches_total": ok,
            }
            if isinstance(pol, RLPoolPolicy):
                cell[pol_name]["trained"] = bool(pol.trained)
                rl_trained &= bool(pol.trained)
        harvest_used += cell["portfolio"].get("cost_harvest", 0.0) > 0
        port_wins.append(
            cell["portfolio"]["objective"] < cell["reactive"]["objective"]
        )
        # either deployment mode of the controller counts (see the RL
        # bench: greedy is usually the stronger one at 108 actions)
        rl_wins.append(
            min(cell["rl_pool"]["objective"],
                cell["rl_pool_greedy"]["objective"])
            < cell["reactive"]["objective"]
        )
        payload["grid"][name] = cell

    n_sc = len(payload["grid"])
    n_port, n_rl = int(np.sum(port_wins)), int(np.sum(rl_wins))
    payload["claims"] = {
        "scenarios": n_sc,
        "portfolio_beats_reactive_objective": n_port,
        "rl_pool_beats_reactive_objective": n_rl,
        "rl_pool_trained": rl_trained,
        "harvest_tier_engaged": harvest_used,
        "decomposition_sums_everywhere": decomposed,
    }
    write_artifact("BENCH_tier_portfolio", payload)

    registered = (
        VECTOR_SCHEDULERS.get("rl_pool") is RLPoolPolicy
        and "portfolio" in VECTOR_SCHEDULERS
    )
    rows: List[Row] = [
        ("portfolio_and_rl_registered", float(registered),
         "portfolio + rl_pool registered in VECTOR_SCHEDULERS", registered),
        ("scenarios", float(n_sc), "grid covers the 7-scenario zoo", n_sc >= 7),
        ("decomposition_sums", float(decomposed),
         "per-tier cost decomposition sums to the ledger total, every cell",
         decomposed),
        ("portfolio_beats_reactive", float(n_port),
         "portfolio beats reserved-only reactive on blended objective on "
         ">= 5 of 7 zoo scenarios", n_port >= 5),
        ("portfolio_harvest_engaged", float(harvest_used),
         "the harvest tier carries load on every scenario",
         harvest_used == n_sc),
        ("rl_beats_reactive", float(n_rl),
         "trained rl_pool (spot head) beats reactive on blended objective "
         "on >= 5 of 7 (reported only when untrained fallback weights ran)",
         n_rl >= 5 or not rl_trained),
    ]
    return print_rows("tier_portfolio", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
